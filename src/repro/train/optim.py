"""Optimizers in pure JAX (no optax dependency).

AdamW for ≤30B-class models; Adafactor (factored second moment, no first
moment by default) for the 1T-parameter MoE — at that scale full Adam
moments (8 bytes/param fp32) exceed 512×16 GB HBM, while factored stats are
O(rows+cols). The launcher picks per-arch (configs set stream_weights/size).
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- AdamW ----

def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.01):
    step = state["step"] + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * jnp.square(g32)
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
        p_new = p.astype(jnp.float32) - lr * (update + weight_decay * p.astype(jnp.float32))
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}


# ------------------------------------------------------------ Adafactor ----

def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params):
    def stat(p):
        if _factored(p.shape):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),       # row stats
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

    return {
        "stats": jax.tree_util.tree_map(
            stat, params, is_leaf=lambda x: isinstance(x, jnp.ndarray)),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(params, grads, state, lr=1e-2, decay=0.8, eps=1e-30,
                     clip_threshold=1.0, weight_decay=0.0):
    step = state["step"] + 1
    beta = 1.0 - step.astype(jnp.float32) ** -decay

    def upd(p, g, s):
        g32 = g.astype(jnp.float32)
        g2 = jnp.square(g32) + eps
        if _factored(p.shape):
            vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
            vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
            denom = jnp.mean(vr, axis=-1, keepdims=True)
            r = (vr / jnp.maximum(denom, eps))[..., None]
            u = g32 * jax.lax.rsqrt(jnp.maximum(r, eps)) * \
                jax.lax.rsqrt(jnp.maximum(vc[..., None, :], eps))
            new_s = {"vr": vr, "vc": vc}
        else:
            v = beta * s["v"] + (1 - beta) * g2
            u = g32 * jax.lax.rsqrt(jnp.maximum(v, eps))
            new_s = {"v": v}
        # Update clipping (RMS ≤ clip_threshold).
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        p_new = p.astype(jnp.float32) - lr * (u + weight_decay * p.astype(jnp.float32))
        return p_new.astype(p.dtype), new_s

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_s = tdef.flatten_up_to(state["stats"])
    out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_s = tdef.unflatten([o[1] for o in out])
    return new_p, {"stats": new_s, "step": step}


OPTIMIZERS: Dict[str, Tuple[Callable, Callable]] = {
    "adamw": (adamw_init, adamw_update),
    "adafactor": (adafactor_init, adafactor_update),
}


def make_optimizer(name: str, **hyper):
    init_fn, update_fn = OPTIMIZERS[name]

    def update(params, grads, state):
        return update_fn(params, grads, state, **hyper)

    return init_fn, update
