from repro.train.optim import (
    adamw_init, adamw_update, adafactor_init, adafactor_update,
    OPTIMIZERS, make_optimizer,
)
from repro.train.compression import compress_grads, decompress_grads, ef_init
from repro.train.loop import (
    TrainLoopConfig, gcn_train_loop, make_gcn_train_step, make_train_step,
    train_loop,
)

__all__ = [
    "adamw_init", "adamw_update", "adafactor_init", "adafactor_update",
    "OPTIMIZERS", "make_optimizer",
    "compress_grads", "decompress_grads", "ef_init",
    "TrainLoopConfig", "make_train_step", "train_loop",
    "make_gcn_train_step", "gcn_train_loop",
]
