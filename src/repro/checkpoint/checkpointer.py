"""Atomic, resumable, mesh-shape-agnostic checkpoints.

Design points for 1000+-node deployments:
  * atomicity — write to `step_K.tmp/`, fsync, rename; a crashed writer
    never corrupts the latest checkpoint (restart reads the newest complete
    manifest).
  * restartability — `restore()` rebuilds (params, opt_state, step) from the
    newest complete checkpoint; the data pipeline is seekable by step
    (repro.data.tokens), so resume reproduces the exact batch sequence.
  * elasticity — arrays are saved UNSHARDED by logical name (gathered), so a
    restore can re-shard onto any mesh shape; per-shard saving would pin the
    topology. (At 1T-param scale one would save per-host shards + a reshard
    map; documented trade-off, same manifest format.)
  * retention — keep_last prunes old checkpoints.
"""
from __future__ import annotations

import base64
import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray], skeleton):
    if isinstance(skeleton, dict):
        return {k: _unflatten(
            {kk[len(k) + 1:]: vv for kk, vv in flat.items()
             if kk.split("/")[0] == k}, v)
            for k, v in skeleton.items()}
    if isinstance(skeleton, (list, tuple)):
        typ = type(skeleton)
        return typ(_unflatten(
            {kk[len(str(i)) + 1:]: vv for kk, vv in flat.items()
             if kk.split("/")[0] == str(i)}, v)
            for i, v in enumerate(skeleton))
    return flat[""] if "" in flat else flat[next(iter(flat))]


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            manifest = os.path.join(directory, name, "manifest.json")
            if os.path.exists(manifest):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


class Checkpointer:
    def __init__(self, directory: str, keep_last: int = 3):
        self.directory = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int, tmp: bool = False) -> str:
        return os.path.join(self.directory,
                            f"step_{step}" + (".tmp" if tmp else ""))

    def save(self, step: int, params, opt_state, **extra) -> str:
        tree = {"params": params, "opt_state": opt_state}
        tree.update({k: v for k, v in extra.items() if v is not None})
        # Gather to host (unsharded logical arrays).
        host_tree = jax.tree_util.tree_map(np.asarray, tree)
        flat = _flatten(host_tree)
        tmp = self._path(step, tmp=True)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "keys": sorted(flat.keys()),
            "nbytes": int(sum(a.nbytes for a in flat.values())),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        final = self._path(step)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._prune()
        return final

    def restore(self, skeleton, step: Optional[int] = None) -> Tuple[Any, int]:
        """skeleton: pytree with the target structure (values ignored)."""
        if step is None:
            step = latest_step(self.directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.directory}")
        path = self._path(step)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        flat = {k: data[k] for k in manifest["keys"]}
        tree = _unflatten(flat, skeleton)
        return tree, step

    def _prune(self) -> None:
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[: -self.keep_last]:
            shutil.rmtree(self._path(s), ignore_errors=True)


# ---- segment-brick checkpoints (serving warm start) -------------------------
#
# A serving engine's segment cache holds densified BlockELL bricks whose keys
# are content-addressed (csr_fingerprint namespaces), so they survive process
# restarts. `save_segment_bricks` persists (metadata, arrays) pairs through
# the same atomic Checkpointer machinery (tmp dir + fsync'd manifest +
# rename); `load_segment_bricks` reads the newest complete checkpoint back.
# Brick metadata (the SegmentKey fields + BlockELL geometry) rides inside the
# array names — JSON, urlsafe-base64-encoded so it can never collide with the
# '/' separator of the flattened-tree format — keeping the manifest the
# single source of truth and the publish atomic.
#
# Bricks live in their own `segment_bricks/` subdirectory of the directory
# the caller names: the brick Checkpointer prunes aggressively (keep_last=1),
# and it must never be able to prune — or be confused by — a *training*
# checkpoint the operator keeps in the same place.

BRICKS_SUBDIR = "segment_bricks"


def _encode_brick_meta(meta: Dict[str, Any]) -> str:
    blob = json.dumps(meta, sort_keys=True).encode()
    return base64.urlsafe_b64encode(blob).decode().rstrip("=")


def _decode_brick_meta(token: str) -> Dict[str, Any]:
    pad = "=" * (-len(token) % 4)
    return json.loads(base64.urlsafe_b64decode(token + pad))


def save_segment_bricks(
    directory: str,
    bricks: List[Tuple[Dict[str, Any], Dict[str, np.ndarray]]],
    step: int = 0,
) -> str:
    """Atomically persist cache bricks as (json-able meta, named arrays)."""
    params = {
        _encode_brick_meta(meta): {k: np.asarray(v) for k, v in arrays.items()}
        for meta, arrays in bricks
    }
    target = os.path.join(directory, BRICKS_SUBDIR)
    return Checkpointer(target, keep_last=1).save(step, params, opt_state={})


def load_segment_bricks(
    directory: str,
    step: Optional[int] = None,
) -> List[Tuple[Dict[str, Any], Dict[str, np.ndarray]]]:
    """Read back the newest (or given) brick checkpoint; [] if none.

    Keys that do not parse as brick entries (wrong arity, undecodable
    metadata) are skipped, not fatal: the function may be pointed at a
    directory that predates — or never was — a brick checkpoint.
    """
    target = os.path.join(directory, BRICKS_SUBDIR)
    if step is None:
        step = latest_step(target)
    if step is None:
        return []
    path = os.path.join(target, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    grouped: Dict[str, Dict[str, np.ndarray]] = {}
    for key in manifest["keys"]:
        parts = key.split("/")
        if len(parts) != 3 or parts[0] != "params":
            continue
        grouped.setdefault(parts[1], {})[parts[2]] = data[key]
    out = []
    for token, arrays in grouped.items():
        try:
            meta = _decode_brick_meta(token)
        except (ValueError, json.JSONDecodeError):
            continue
        out.append((meta, arrays))
    return out
