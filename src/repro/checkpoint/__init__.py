from repro.checkpoint.checkpointer import (
    Checkpointer,
    latest_step,
    load_segment_bricks,
    save_segment_bricks,
)

__all__ = ["Checkpointer", "latest_step", "load_segment_bricks",
           "save_segment_bricks"]
